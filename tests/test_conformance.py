"""Adversarial conformance grid — the tier-1 safety net every later
refactor leans on.

Sweeps wire transport {full, digest} x masking {global, pairwise, none}
x executor {sim, mesh} against the strategy set in ``tests/adversary.py``
(crash-at-hop-k, payload corruption, per-copy digest equivocation,
digest/payload mismatch, colluding cluster minority, per-session mixes
in one batch) and pins:

  * exact-output-with-high-probability: every in-bound adversary is
    absorbed BIT-IDENTICALLY to the honest run (the vote/median/backup
    machinery recovers the exact aggregate, which itself matches the
    plain fp32 sum within the quantization bound);
  * MeshTransport == SimTransport bit-exact in every digest and full
    cell (forced multi-device subprocess);
  * the analytic bandwidth model (``schedules.schedule_cost``) equals
    the bytes the engine's compiled plan actually moves;
  * the retired ``core/secure_allreduce`` shim module stays deleted and
    the engine path runs deprecation-clean (the ``repro.api`` facade is
    the only front door — facade == engine is pinned in tests/test_api);
  * the README "Adversary model" table matches the executed grid.
"""
import dataclasses
import os
import subprocess
import sys
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from adversary import (ADVERSARIES, colluding_minority, run_sim_batch,
                       session_faults)
from repro.core.byzantine import ByzantineSpec
from repro.core.masking import quantization_error_bound
from repro.core.plan import AggConfig, SessionMeta, compile_plan
from repro.core.schedules import schedule_cost

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
RNG = np.random.default_rng(0xC0FFEE)

# grid committee: g=4 clusters -> 3 voted ring rounds, so the
# crash-at-hop-k family has hops to crash at
GRID_N, GRID_C, GRID_R, GRID_T = 16, 4, 3, 96


def _grid_cfg(transport: str, masking: str, **kw) -> AggConfig:
    return AggConfig(n_nodes=GRID_N, cluster_size=GRID_C,
                     redundancy=GRID_R, schedule="ring",
                     transport=transport, masking=masking, clip=2.0, **kw)


def _payloads(S: int, n: int = GRID_N, T: int = GRID_T) -> np.ndarray:
    return (RNG.normal(size=(S, n, T)) * 0.2).astype(np.float32)


# ---------------------------------------------------------------------------
# Sim-executor cells: transport x masking, every adversary as one
# session of a single batch (the per-session-mix dimension is built in)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("masking", ["global", "pairwise", "none"])
@pytest.mark.parametrize("transport", ["full", "digest"])
def test_sim_cell_absorbs_every_adversary(transport, masking):
    """One batch, one session per adversary strategy: the faulty batch is
    BIT-IDENTICAL to the honest batch (every strategy absorbed), every
    node row agrees, and the aggregate is the exact sum within the
    quantization bound."""
    S = len(ADVERSARIES)
    cfg = _grid_cfg(transport, masking)
    xs = _payloads(S)
    seeds = jnp.arange(S, dtype=jnp.uint32) + 3
    got, _ = run_sim_batch(cfg, xs, seeds=seeds,
                           faults=session_faults(GRID_N, GRID_C, GRID_R))
    honest, _ = run_sim_batch(cfg, xs, seeds=seeds)
    assert np.array_equal(got, honest)
    assert (honest == honest[:, :1]).all()     # replicated on every node
    bound = quantization_error_bound(cfg.mask_cfg()) * 4
    assert np.abs(honest - xs.sum(1, keepdims=True)).max() < bound


@pytest.mark.parametrize("transport", ["full", "digest"])
def test_crash_at_every_hop_k(transport):
    """The ``drop@k`` family across all 3 ring rounds: a crash at any
    hop is vote-absorbed (the crashed node's contribution was already
    merged at the intra-cluster sum)."""
    cfg = _grid_cfg(transport, "global")
    xs = _payloads(1)
    honest, _ = run_sim_batch(cfg, xs)
    ranks = tuple(cl * GRID_C + cl % GRID_C for cl in range(GRID_N // GRID_C))
    for k in range(3):
        specs = (ByzantineSpec(corrupt_ranks=ranks, mode=f"drop@{k}"),)
        got, _ = run_sim_batch(cfg, xs, faults=[specs])
        assert np.array_equal(got, honest), k


@pytest.mark.parametrize("transport", ["full", "digest"])
def test_colluding_minority_r5_bound(transport):
    """Two colluders per cluster at r=5 — the (1/2 - eps) per-vote bound
    with non-adjacent members, so the digest backup sender stays honest
    whenever the payload sender is corrupt."""
    n, c, r = 16, 8, 5
    cfg = AggConfig(n_nodes=n, cluster_size=c, redundancy=r,
                    transport=transport, clip=2.0)
    adv = colluding_minority(r)
    assert len(adv.ranks(n, c, r)) == (n // c) * 2
    xs = _payloads(1, n=n, T=64)
    honest, _ = run_sim_batch(cfg, xs)
    got, _ = run_sim_batch(cfg, xs, faults=[adv.specs(n, c, r)])
    assert np.array_equal(got, honest)


def test_static_spec_equals_runtime_masks():
    """The plan's static fault model (``AggConfig.byzantine`` ->
    ``plan.faults``) and the per-session runtime masks corrupt
    identically — both absorbed, bit-identical to each other and to the
    honest run (digest cell, the mismatch adversary)."""
    adv = next(a for a in ADVERSARIES if a.mode == "mismatch")
    specs = adv.specs(GRID_N, GRID_C, GRID_R)
    cfg = _grid_cfg("digest", "global")
    xs = _payloads(1)
    honest, _ = run_sim_batch(cfg, xs)
    runtime, _ = run_sim_batch(cfg, xs, faults=[specs])
    static, _ = run_sim_batch(
        dataclasses.replace(cfg, byzantine=specs[0]), xs)
    assert np.array_equal(runtime, static)
    assert np.array_equal(runtime, honest)


def test_digest_without_backup_detects_but_cannot_recover():
    """``digest_backup=False`` (the analytic-retransmission model): a
    rejected payload is detected but consumed, so only the adversaries
    that never get a payload rejected stay absorbed — exactly the README
    table's no-backup column."""
    cfg = _grid_cfg("digest", "global", digest_backup=False)
    xs = _payloads(1)
    honest, _ = run_sim_batch(cfg, xs)
    for adv in ADVERSARIES:
        if adv.mode is None:
            continue
        got, _ = run_sim_batch(cfg, xs,
                               faults=[adv.specs(GRID_N, GRID_C, GRID_R)])
        if adv.survives_digest_nobackup:
            assert np.array_equal(got, honest), adv.name
        else:
            assert not np.array_equal(got, honest), adv.name


# ---------------------------------------------------------------------------
# Bandwidth accounting: analytic cost model == bytes the compiled plan
# actually moves (catches drift between schedules.py and the engine)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport,backup", [("full", False),
                                              ("digest", True),
                                              ("digest", False)])
@pytest.mark.parametrize("schedule", ["ring", "tree", "butterfly"])
def test_bandwidth_accounting_matches_engine(schedule, transport, backup):
    cfg = AggConfig(n_nodes=GRID_N, cluster_size=GRID_C, redundancy=GRID_R,
                    schedule=schedule, transport=transport,
                    digest_backup=backup, clip=2.0)
    T = 256
    xs = _payloads(1, T=T)
    _, got_bytes = run_sim_batch(cfg, xs)
    k = schedule_cost(schedule, GRID_N // GRID_C, GRID_C, GRID_R,
                      payload_bytes=4 * T,
                      digest=(transport == "digest"),
                      digest_bytes=4 * cfg.digest_words,
                      digest_backup=backup)
    assert got_bytes == k["bytes_total"]
    if transport == "digest":
        full = schedule_cost(schedule, GRID_N // GRID_C, GRID_C, GRID_R,
                             payload_bytes=4 * T)
        assert got_bytes < full["bytes_total"]   # the paper's point


def test_bandwidth_accounting_chunked_and_batched():
    """Batching S sessions moves S times the single-session bytes.
    Chunking over K hops preserves the payload bytes exactly; on the
    digest transport every chunk hop is independently digest-checked, so
    K chunks ship K digest sets — the account must show exactly the
    (K-1) extra sets and nothing else."""
    from repro.core.engine import SimTransport, execute_chunks

    def run_chunked(cfg, x, K):
        plan = compile_plan(cfg)
        tp = SimTransport(plan, S=1)
        flat = jnp.asarray(x).astype(jnp.float32)
        Tc = flat.shape[-1] // K
        execute_chunks(plan, tp, [flat[:, k * Tc:(k + 1) * Tc]
                                  for k in range(K)],
                       SessionMeta.single(cfg.seed))
        return tp.bytes_sent

    T, S = 256, 3
    xs = _payloads(S, T=T)
    for transport in ("full", "digest"):
        cfg = _grid_cfg(transport, "global")
        _, one = run_sim_batch(cfg, xs[:1])
        _, batched = run_sim_batch(cfg, xs)
        assert batched == S * one
        chunked = run_chunked(cfg, xs[0], K=2)
        if transport == "full":
            assert chunked == one
        else:
            digest_set = sum(
                len(p) for rnd in compile_plan(cfg).rounds
                for p in rnd.perms) * cfg.digest_words * 4
            assert chunked == one + digest_set


# ---------------------------------------------------------------------------
# Service executor: the digest transport through the batched service
# path (sim executor in-process; mesh executor in the subprocess below)
# ---------------------------------------------------------------------------


def test_service_digest_transport_sim_executor():
    from repro.runtime.fault import SessionFaultPlan
    from repro.service import (AggregationService, BatchingConfig,
                               SessionParams)
    n, elems, S = 8, 50, 4
    vals = (RNG.normal(size=(S, n, elems)) * 0.3).astype(np.float32)
    params = SessionParams(n_nodes=n, elems=elems, cluster_size=4,
                           redundancy=3, masking="pairwise",
                           transport="digest", clip=2.0)
    # transports never share a batch: the wire transport is in the key
    assert params.batch_key(64) != dataclasses.replace(
        params, transport="full").batch_key(64)
    svc = AggregationService(params,
                             batching=BatchingConfig(max_batch=S,
                                                     max_age=1e9))
    for i in range(S):
        s = svc.open(now=0.0)
        for slot in range(n):
            if (i, slot) != (1, 2):          # one missing slot -> crash
                s.contribute(slot, vals[i, slot])
        if i == 2:
            s.inject_fault(SessionFaultPlan(byzantine_slots=(5,),
                                            byzantine_mode="equivocate"))
        if i == 3:
            s.inject_fault(SessionFaultPlan(byzantine_slots=(0,),
                                            byzantine_mode="mismatch"))
        svc.seal(s.sid, now=0.0)
    assert svc.pump(force=True) == S
    got = np.stack([svc.result(sid) for sid in range(S)])
    want = vals.sum(1)
    want[1] -= vals[1, 2]
    assert np.abs(got - want).max() < 1e-3


# ---------------------------------------------------------------------------
# Mesh-executor cells (forced multi-device subprocesses)
# ---------------------------------------------------------------------------


_MESH_GRID = """
import numpy as np, jax.numpy as jnp
from adversary import ADVERSARIES, run_sim_batch, session_faults
from repro.core.engine import MeshTransport
from repro.core.plan import AggConfig, SessionMeta, compile_plan
from repro.runtime import compat

n, c, r, T = 16, 4, 3, 64
S = len(ADVERSARIES)
rng = np.random.default_rng(13)
xs = (rng.normal(size=(S, n, T)) * 0.2).astype(np.float32)
seeds = jnp.arange(S, dtype=jnp.uint32) + 3
faults = session_faults(n, c, r)
mesh = compat.make_mesh((n,), ("data",))
for transport in ("full", "digest"):
    for masking in ("global", "pairwise", "none"):
        cfg = AggConfig(n_nodes=n, cluster_size=c, redundancy=r,
                        schedule="ring", transport=transport,
                        masking=masking, clip=2.0)
        plan = compile_plan(cfg)
        meta = SessionMeta.build(S, n, seed=cfg.seed, seeds=seeds,
                                 faults=faults)
        mt = MeshTransport(mesh, ("data",))
        got = np.asarray(mt.execute(plan, jnp.asarray(xs), meta))
        want, sim_bytes = run_sim_batch(cfg, xs, seeds=seeds, faults=faults)
        assert np.array_equal(got, want), (transport, masking)
        assert mt.last_bytes == sim_bytes, (transport, masking)
        honest, _ = run_sim_batch(cfg, xs, seeds=seeds)
        assert np.array_equal(got, honest), (transport, masking)
        assert np.abs(got[:, 0] - xs.sum(1)).max() < 1e-3, (transport,
                                                            masking)
print("MESH GRID OK")
"""


_SERVICE_DIGEST_MESH = """
import numpy as np
from repro.runtime import compat
from repro.runtime.fault import SessionFaultPlan
from repro.service import AggregationService, BatchingConfig, SessionParams

n, elems, S = 8, 100, 4
rng = np.random.default_rng(21)
vals = (rng.normal(size=(S, n, elems)) * 0.3).astype(np.float32)
params = SessionParams(n_nodes=n, elems=elems, cluster_size=4, redundancy=3,
                       masking="pairwise", transport="digest", clip=2.0)

def run(transport):
    mesh = compat.make_mesh((n,), ("data",)) if transport == "mesh" else None
    svc = AggregationService(
        params, batching=BatchingConfig(max_batch=S, max_age=1e9),
        transport=transport, mesh=mesh)
    for i in range(S):
        s = svc.open(now=0.0)
        for slot in range(n):
            if (i, slot) != (1, 2):          # one missing slot -> crash
                s.contribute(slot, vals[i, slot])
        if i == 2:
            s.inject_fault(SessionFaultPlan(byzantine_slots=(5,),
                                            byzantine_mode="equivocate"))
        if i == 3:
            s.inject_fault(SessionFaultPlan(byzantine_slots=(0,),
                                            byzantine_mode="mismatch"))
        svc.seal(s.sid, now=0.0)
    assert svc.pump(force=True) == S
    return np.stack([svc.result(sid) for sid in range(S)])

sim, mesh = run("sim"), run("mesh")
assert np.array_equal(sim, mesh)
want = vals.sum(1); want[1] -= vals[1, 2]
assert np.abs(sim - want).max() < 1e-3
print("SERVICE DIGEST MESH==SIM")
"""


def _run_sub(code: str, devices: int, timeout: int = 900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, os.path.dirname(__file__), env.get("PYTHONPATH", "")])
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.mesh
@pytest.mark.slow
def test_mesh_cells_bit_identical_to_sim_16dev():
    """The mesh half of the grid: every transport x masking cell with
    the full adversary batch — MeshTransport == SimTransport bit-exact,
    adversaries absorbed, bandwidth accounts equal."""
    r = _run_sub(_MESH_GRID, devices=16)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "MESH GRID OK" in r.stdout


@pytest.mark.mesh
@pytest.mark.slow
def test_service_digest_batch_on_mesh_matches_sim_8dev():
    """A sealed digest-transport service batch (pairwise masking,
    missing contributor, equivocate + mismatch slots) through
    BatchedExecutor(transport="mesh") == the sim executor, bit for bit."""
    r = _run_sub(_SERVICE_DIGEST_MESH, devices=8)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "SERVICE DIGEST MESH==SIM" in r.stdout


# ---------------------------------------------------------------------------
# Shim retirement: core/secure_allreduce is gone; repro.api is the door
# ---------------------------------------------------------------------------


def test_secure_allreduce_shim_module_stays_deleted():
    """The one-release deprecation window closed: the legacy module (and
    with it every ``secure_allreduce_*`` entry point) must not come
    back — new code goes through ``repro.api.SecureAggregator``
    (pinned bit-identical to the engine in tests/test_api.py)."""
    with pytest.raises(ModuleNotFoundError):
        import repro.core.secure_allreduce  # noqa: F401


def test_engine_path_emits_no_deprecation_warnings():
    """A full digest/pairwise adversary cell runs deprecation-clean —
    nothing under the engine path touches a retired entry point (the
    api-lane sweeps the whole tier-1 suite the same way)."""
    cfg = _grid_cfg("digest", "pairwise")
    xs = _payloads(2)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        run_sim_batch(cfg, xs,
                      faults=[(), ADVERSARIES[-1].specs(GRID_N, GRID_C,
                                                        GRID_R)])


# ---------------------------------------------------------------------------
# README "Adversary model" table == the executed grid
# ---------------------------------------------------------------------------


def test_readme_adversary_table_matches_grid():
    """Every non-trivial grid adversary has a README table row whose
    survive cells (full / digest / digest-no-backup) equal the harness's
    expectations — the documented guarantees cannot drift from the
    suite."""
    readme = os.path.join(os.path.dirname(__file__), "..", "README.md")
    with open(readme, encoding="utf-8") as f:
        text = f.read()
    assert "## Adversary model" in text
    section = text.split("## Adversary model", 1)[1].split("\n## ", 1)[0]
    rows = [l for l in section.splitlines() if l.strip().startswith("|")]
    for adv in ADVERSARIES:
        if adv.mode is None:
            continue
        row = [l for l in rows if adv.name in l]
        assert len(row) == 1, (adv.name, row)
        cells = [c.strip() for c in row[0].strip().strip("|").split("|")]
        got = tuple("✓" in c for c in cells[-3:])
        want = (adv.survives_full, adv.survives_digest,
                adv.survives_digest_nobackup)
        assert got == want, (adv.name, got, want)
