"""Cuckoo-rule overlay invariants: Θ(log n) clusters with honest majority
w.h.p. (the paper's Remark 1 precondition)."""
import pytest

from repro.core.overlay import Overlay, build_overlay


@pytest.mark.parametrize("n,tau", [(256, 0.2), (256, 0.3), (512, 0.3)])
def test_honest_majority_after_bootstrap(n, tau):
    ov = build_overlay(n, tau, seed=0)
    inv = ov.check_invariants()
    assert inv["all_honest_majority"], inv
    assert inv["min_size"] >= 2
    assert inv["max_size"] <= 8 * inv["mean_size"]


def test_invariants_survive_churn():
    ov = build_overlay(256, 0.3, seed=1)
    uids = list(ov.nodes)
    for i in range(40):  # alternating leave/join
        ov.leave(uids[i])
        ov.join(honest=(i % 3 != 0))
    inv = ov.check_invariants()
    assert inv["honest_majority_frac"] >= 0.95, inv


def test_join_cost_is_polylog():
    ov = build_overlay(256, 0.3, seed=2)
    before = ov.stats.messages
    ov.join(honest=True)
    cost = ov.stats.messages - before
    import math
    assert cost < 60 * math.log2(256) ** 3


def test_positions_in_unit_interval():
    ov = build_overlay(64, 0.2, seed=3)
    assert all(0.0 <= nd.pos < 1.0 for nd in ov.nodes.values())
