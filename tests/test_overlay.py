"""Cuckoo-rule overlay invariants: Θ(log n) clusters with honest majority
w.h.p. (the paper's Remark 1 precondition)."""
import pytest

from repro.core.overlay import Overlay, build_overlay


@pytest.mark.parametrize("n,tau", [(256, 0.2), (256, 0.3), (512, 0.3)])
def test_honest_majority_after_bootstrap(n, tau):
    ov = build_overlay(n, tau, seed=0)
    inv = ov.check_invariants()
    assert inv["all_honest_majority"], inv
    assert inv["min_size"] >= 2
    assert inv["max_size"] <= 8 * inv["mean_size"]


def test_invariants_survive_churn():
    ov = build_overlay(256, 0.3, seed=1)
    uids = list(ov.nodes)
    for i in range(40):  # alternating leave/join
        ov.leave(uids[i])
        ov.join(honest=(i % 3 != 0))
    inv = ov.check_invariants()
    assert inv["honest_majority_frac"] >= 0.95, inv


def test_join_cost_is_polylog():
    ov = build_overlay(256, 0.3, seed=2)
    before = ov.stats.messages
    ov.join(honest=True)
    cost = ov.stats.messages - before
    import math
    assert cost < 60 * math.log2(256) ** 3


def test_long_interleaved_churn_keeps_invariants():
    """Long alternating join/leave traffic (several times the network
    size in churn events): cluster sizes stay Θ(log n) — bounded within
    a constant factor of the mean — and the honest-majority fraction
    stays w.h.p.-high throughout, checked at regular probes rather than
    only at the end."""
    import math
    import random as _r
    ov = build_overlay(512, 0.3, seed=7)
    rng = _r.Random(99)
    logn = math.log2(512)
    for step in range(600):
        if ov.nodes and rng.random() < 0.5:
            ov.leave(rng.choice(list(ov.nodes)))
        else:
            # keep the adversarial fraction near tau on average
            ov.join(honest=rng.random() >= 0.3)
        if step % 100 == 99:
            inv = ov.check_invariants()
            assert inv["min_size"] >= 1, inv
            assert inv["max_size"] <= 10 * inv["mean_size"], inv
            assert inv["mean_size"] >= logn / 4, inv
            assert inv["honest_majority_frac"] >= 0.9, inv


def test_churn_epoch_manager_tracks_departures():
    """EpochManager snapshots are stable under overlay churn; departed
    committee members are reported for exactly the old epoch."""
    from repro.service import EpochManager
    ov = build_overlay(256, 0.2, seed=11)
    em = EpochManager(ov, cluster_size=4)
    snap = em.current()
    victim = snap.slot_uids[1]
    ov.leave(victim)
    assert set(em.departed_slots(snap)) == set(snap.slots_of(victim))
    new = em.advance()
    assert victim not in new.slot_uids
    assert em.departed_slots(new) == ()


def test_positions_in_unit_interval():
    ov = build_overlay(64, 0.2, seed=3)
    assert all(0.0 <= nd.pos < 1.0 for nd in ov.nodes.values())
