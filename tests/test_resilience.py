"""Chaos-injected resilience conformance: the retry -> bisect ->
quarantine ladder, session deadlines, load shedding, and the mesh->sim
circuit-breaker degrade ladder, driven by ``runtime.chaos``.

The acceptance grid: every chaos mode x transport {sim, mesh} x retry
outcome {recovered, bisected, quarantined} —

  * surviving sessions REVEAL bit-identical to a fault-free run
    (chaos faults raise or delay, never corrupt payloads);
  * quarantined sessions land in the executor's dead-letter list with
    the triggering error;
  * no session is ever left in AGGREGATING.

The mesh half of the grid runs in a forced-8-device subprocess (marked
``mesh``/``slow``, like the engine equivalence cells); everything else
runs single-host on the sim oracle — `make chaos-lane` sweeps this file
minus the mesh cell over the fixed chaos seeds baked into the
parametrizations.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.runtime.chaos import (ChaosConfig, ChaosError, ChaosSchedule,
                                 ChaosTransport)
from repro.runtime.resilience import (CircuitBreaker, DeadlineExceeded,
                                      ResilienceError, RetryPolicy)
from repro.service import (AggregationService, BatchingConfig, LifecycleError,
                           SessionParams, SessionState, StreamConfig)

pytestmark = pytest.mark.chaos

RNG = np.random.default_rng(23)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N, ELEMS = 8, 16


def _params(elems=ELEMS):
    return SessionParams(n_nodes=N, elems=elems, cluster_size=4,
                         redundancy=3)


def _service(S=6, vals=None, batching=None, **kw):
    """A sim-oracle service pre-loaded with S sealed sessions carrying
    ``vals`` (S, n, elems); fresh service => sids 0..S-1, so two
    services fed the same vals derive identical pad keys."""
    svc = AggregationService(
        _params(), batching=batching or BatchingConfig(max_batch=64,
                                                       max_age=1e9), **kw)
    sessions = []
    for i in range(S):
        s = svc.open(now=0.0)
        for slot in range(N):
            s.contribute(slot, vals[i, slot])
        svc.seal(s.sid, now=0.0)
        sessions.append(s)
    return svc, sessions


def _vals(S=6):
    return RNG.normal(size=(S, N, ELEMS)).astype(np.float32) * 0.3


def _reference(vals):
    """Fault-free run of the same sessions (same sids => same pad
    keys): the bit-identity oracle for every chaos scenario."""
    svc, sessions = _service(S=len(vals), vals=vals)
    assert svc.pump(force=True) == len(vals)
    return np.stack([s.result for s in sessions])


# ---------------------------------------------------------------------------
# Outcome "recovered": every chaos mode, transient fault, retry wins
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chaos", [
    ChaosConfig(mode="dispatch", times=1),
    ChaosConfig(mode="compile", times=1),
    ChaosConfig(mode="hop", hop_k=0, times=1),
    # the 1.0s stall alone exceeds the 0.5s deadline, so attempt 1
    # fails deterministically; its completed dispatch warms the jitted
    # fn, so the clean retry finishes far under the deadline
    ChaosConfig(mode="slow", slow_s=1.0, times=1),
], ids=lambda c: c.mode)
def test_transient_fault_recovers_bit_identical(chaos):
    """One injected fault per mode; the retry succeeds and the batch
    reveals bit-identical to the fault-free run."""
    vals = _vals()
    retry = RetryPolicy(
        max_attempts=3, base_backoff_s=0.0,
        deadline_s=0.5 if chaos.mode == "slow" else None)
    svc, sessions = _service(vals=vals, retry=retry, chaos=chaos)
    assert svc.pump(force=True) == 6
    assert np.array_equal(np.stack([s.result for s in sessions]),
                          _reference(vals))
    res = svc.stats["resilience"]
    assert res["chaos_injected"] == 1
    assert res["retries"] == 1
    assert res["quarantined"] == 0 and res["dead_letter"] == ()
    assert res["deadline_hits"] == (1 if chaos.mode == "slow" else 0)
    assert all(s.state is SessionState.REVEALED for s in sessions)


# ---------------------------------------------------------------------------
# Outcomes "bisected" / "quarantined": poison isolation, dead letter
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["dispatch", "hop"])
def test_poison_session_bisected_into_dead_letter(mode):
    """A fault pinned to one session (``poison_sids``) exhausts the
    batch's attempts, bisection isolates it, the survivors reveal
    bit-identical, and the poison lands in the dead letter FAILED."""
    vals = _vals()
    poison = 3
    chaos = ChaosConfig(mode=mode, hop_k=0, poison_sids=(poison,))
    svc, sessions = _service(
        vals=vals, chaos=chaos,
        retry=RetryPolicy(max_attempts=2, base_backoff_s=0.0))
    assert svc.pump(force=True) == 6       # executed incl. the quarantine
    ref = _reference(vals)
    for i, s in enumerate(sessions):
        if i == poison:
            assert s.state is SessionState.FAILED
            assert "chaos" in s.failed_reason
        else:
            assert s.state is SessionState.REVEALED
            assert np.array_equal(s.result, ref[i])
    res = svc.stats["resilience"]
    assert res["bisections"] == 2          # [0..5] -> [3,4,5] -> [3]
    assert res["quarantined"] == 1
    assert len(res["dead_letter"]) == 1
    sid, err = res["dead_letter"][0]
    assert sid == poison and "chaos" in err
    assert svc.stats["sessions"]["failed"] == 1


def test_whole_batch_quarantined_without_bisection():
    """``bisect=False`` restores whole-batch quarantine: a persistent
    fault fails every session, all land in the dead letter, and the
    pump re-raises the triggering error (nothing survived)."""
    vals = _vals(S=4)
    svc, sessions = _service(
        S=4, vals=vals, chaos=ChaosConfig(mode="dispatch"),
        retry=RetryPolicy(max_attempts=2, base_backoff_s=0.0, bisect=False))
    with pytest.raises(ChaosError):
        svc.pump(force=True)
    assert all(s.state is SessionState.FAILED for s in sessions)
    res = svc.stats["resilience"]
    assert res["bisections"] == 0 and res["quarantined"] == 4
    assert sorted(sid for sid, _ in res["dead_letter"]) == [0, 1, 2, 3]
    assert svc.pump(force=True) == 0       # queue fully drained


def test_pump_isolates_poisoned_key_and_reraises_after_sweep():
    """A key whose whole batch is quarantined must not starve the other
    keys: the pump finishes the sweep, then re-raises the first error."""
    vals = _vals(S=2)
    svc = AggregationService(
        _params(), batching=BatchingConfig(max_batch=64, max_age=1e9),
        chaos=ChaosConfig(mode="dispatch", poison_sids=(0,)),
        retry=RetryPolicy(max_attempts=1))
    sa = svc.open(now=0.0)                           # key A: elems=16
    for slot in range(N):
        sa.contribute(slot, vals[0, slot])
    svc.seal(sa.sid, now=0.0)
    sb = svc.open(params=_params(elems=100), now=0.0)   # key B: elems=100
    for slot in range(N):
        sb.contribute(slot, np.full(100, 0.25, np.float32))
    svc.seal(sb.sid, now=0.0)
    with pytest.raises(ChaosError):
        svc.pump(force=True)
    assert sa.state is SessionState.FAILED           # key A quarantined
    assert sb.state is SessionState.REVEALED         # key B still ran
    assert np.allclose(sb.result, np.full(100, 0.25 * N), atol=1e-4)
    assert svc.queue.depth() == 0


# ---------------------------------------------------------------------------
# Chaos storm over the fixed seed sweep (the chaos-lane anchor)
# ---------------------------------------------------------------------------


def _storm(seed):
    vals = _vals(S=8)
    svc, sessions = _service(
        S=8, vals=vals, chaos=ChaosConfig(mode="dispatch", p=0.4, seed=seed),
        retry=RetryPolicy(max_attempts=2, base_backoff_s=0.0))
    try:
        svc.pump(force=True)
    except ChaosError:
        pass                                          # all-failed batch
    ref = _reference(vals)
    dead = dict(svc.executor.dead_letter)
    failed = []
    for i, s in enumerate(sessions):
        # terminal, never wedged in AGGREGATING
        assert s.state in (SessionState.REVEALED, SessionState.FAILED)
        if s.state is SessionState.REVEALED:
            assert np.array_equal(s.result, ref[i])   # survivors exact
        else:
            failed.append(s.sid)
            assert "chaos" in dead[s.sid]             # dead-lettered
    return tuple(failed), svc.stats["resilience"]["chaos_injected"]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_storm_is_terminal_exact_and_replayable(seed):
    """Random fault storm at p=0.4: every session ends terminal,
    survivors bit-identical, quarantines dead-lettered — and the whole
    outcome replays exactly from the seed."""
    assert _storm(seed) == _storm(seed)


# ---------------------------------------------------------------------------
# Session deadlines and load shedding
# ---------------------------------------------------------------------------


def test_session_deadline_expires_at_pump_not_aggregates():
    vals = _vals(S=2)
    svc = AggregationService(
        _params(), batching=BatchingConfig(max_batch=64, max_age=1e9))
    doomed = svc.open(now=0.0, ttl=5.0)
    live = svc.open(now=0.0)                    # no ttl: never expires
    for slot in range(N):
        doomed.contribute(slot, vals[0, slot])
        live.contribute(slot, vals[1, slot])
    svc.seal(doomed.sid, now=0.0)
    svc.seal(live.sid, now=0.0)
    assert svc.pump(now=10.0, force=True) == 1  # only the live session
    assert doomed.state is SessionState.EXPIRED
    assert "deadline" in doomed.failed_reason
    assert live.state is SessionState.REVEALED
    assert svc.queue.metrics["expired_sessions"] == 1
    with pytest.raises(LifecycleError):
        _ = doomed.result
    svc.evict(doomed.sid)                       # EXPIRED is evictable
    with pytest.raises(KeyError):
        svc.result(doomed.sid)


def test_default_ttl_comes_from_batching_config():
    svc = AggregationService(
        _params(), batching=BatchingConfig(max_batch=64, max_age=1e9,
                                           session_ttl=7.0))
    s = svc.open(now=1.0)
    assert s.expires_at == 8.0
    assert svc.open(now=1.0, ttl=2.0).expires_at == 3.0


def test_force_pump_drains_expired_keys_under_logical_ticks():
    """A key whose every member expired must drain cleanly on a forced
    pump (shutdown path) — no empty-batch dispatch, no leftover key."""
    svc = AggregationService(
        _params(), batching=BatchingConfig(max_batch=64, max_age=1e9))
    doomed = [svc.open(now=0.0, ttl=1.0) for _ in range(3)]
    for s in doomed:
        for slot in range(N):
            s.contribute(slot, np.zeros(ELEMS, np.float32))
        svc.seal(s.sid, now=0.0)
    assert svc.pump(now=50.0, force=True) == 0
    assert all(s.state is SessionState.EXPIRED for s in doomed)
    assert svc.queue.depth() == 0 and not svc.queue._pending
    assert svc.queue.metrics["expired_sessions"] == 3
    assert svc.executor.batches_run == 0


def test_load_shedding_sheds_newest_over_high_watermark():
    svc = AggregationService(
        _params(), batching=BatchingConfig(max_batch=64, max_age=1e9,
                                           max_pending_rows=4))
    sessions = []
    for i in range(6):
        s = svc.open(now=float(i))
        for slot in range(N):
            s.contribute(slot, np.zeros(ELEMS, np.float32))
        svc.seal(s.sid, now=float(i))
        sessions.append(s)
    m = svc.queue.metrics
    assert m["shed_sessions"] == 2 and m["pending_rows"] == 4
    assert m["flush_reasons"]["shed"] == 2
    # newest arrivals shed; the 4 oldest survive to reveal
    assert [s.state for s in sessions[4:]] == [SessionState.EXPIRED] * 2
    assert all("shed" in s.failed_reason for s in sessions[4:])
    assert svc.pump(force=True) == 4
    assert all(s.state is SessionState.REVEALED for s in sessions[:4])


def test_shedding_is_weighted_fair_protects_old_keys():
    """Victims come from the big YOUNG key (a flood), never from the
    old key already near its age watermark."""
    svc = AggregationService(
        _params(), batching=BatchingConfig(max_batch=64, max_age=1e9,
                                           max_pending_rows=3))
    old = []
    for _ in range(2):                       # key A: elems=16, sealed at 0
        s = svc.open(now=0.0)
        for slot in range(N):
            s.contribute(slot, np.zeros(ELEMS, np.float32))
        svc.seal(s.sid, now=0.0)
        old.append(s)
    flood = []
    for _ in range(3):                       # key B: elems=100, sealed late
        s = svc.open(params=_params(elems=100), now=10.0)
        for slot in range(N):
            s.contribute(slot, np.zeros(100, np.float32))
        svc.seal(s.sid, now=10.0)
        flood.append(s)
    assert all(s.state is SessionState.SEALED for s in old)
    assert [s.state for s in flood] == [SessionState.SEALED,
                                        SessionState.EXPIRED,
                                        SessionState.EXPIRED]
    assert svc.queue.metrics["shed_sessions"] == 2


# ---------------------------------------------------------------------------
# Degrade ladder: circuit breaker falls back to the sim oracle
# ---------------------------------------------------------------------------


def test_breaker_trips_degrades_and_reprobes_single_host():
    """Mesh executor on a 1-device host: dispatch-chaos pinned to the
    mesh backend kills every mesh attempt before it touches the mesh,
    so the ladder is observable anywhere — trip after k=2 consecutive
    failures, run degraded on sim (bit-identical), re-probe after the
    cooloff, failed probe restarts it."""
    clk = {"t": 0.0}
    brk = CircuitBreaker(k=2, cooloff_s=50.0, clock=lambda: clk["t"])
    vals = _vals()
    svc, sessions = _service(
        vals=vals, transport="mesh", mesh=object(),   # never dereferenced
        retry=RetryPolicy(max_attempts=3, base_backoff_s=0.0),
        breaker=brk, chaos=ChaosConfig(mode="dispatch",
                                       only_backend="mesh"))
    # batch 1: mesh fails twice -> breaker opens; 3rd attempt on sim
    assert svc.pump(force=True) == 6
    assert np.array_equal(np.stack([s.result for s in sessions]),
                          _reference(vals))
    res = svc.stats["resilience"]
    assert brk.state == "open" and brk.trips == 1
    assert res["degraded_batches"] == 1 and res["retries"] == 2
    # batch 2 while open: straight to sim, no mesh attempt burned
    s2 = svc.open(now=0.0)
    for slot in range(N):
        s2.contribute(slot, vals[0, slot])
    svc.seal(s2.sid, now=0.0)
    assert svc.pump(force=True) == 1
    assert s2.state is SessionState.REVEALED
    res = svc.stats["resilience"]
    assert res["degraded_batches"] == 2 and res["retries"] == 2
    assert res["breaker"]["state"] == "open"
    # cooloff elapsed: one probe goes back to mesh, chaos kills it,
    # the cooloff restarts and the batch still reveals on sim
    clk["t"] = 100.0
    s3 = svc.open(now=0.0)
    for slot in range(N):
        s3.contribute(slot, vals[1, slot])
    svc.seal(s3.sid, now=0.0)
    assert svc.pump(force=True) == 1
    assert s3.state is SessionState.REVEALED
    assert brk.probes == 1 and brk.state == "open"
    assert svc.stats["resilience"]["degraded_batches"] == 3


def test_facade_surfaces_degradation():
    from repro.api import SecureAggregator, Topology
    brk = CircuitBreaker(k=1, cooloff_s=1e9)
    agg = SecureAggregator(topology=Topology(n_nodes=N), breaker=brk)
    s = agg.open_session(4)
    for slot in range(N):
        s.contribute(slot, np.zeros(4, np.float32))
    agg.seal(s.sid)
    agg.drain()
    assert agg.stats()["degraded"] is False
    brk.record_failure()                     # k=1: one failure trips it
    assert agg.stats()["degraded"] is True
    assert agg.stats()["service"]["resilience"]["breaker"]["trips"] == 1


# ---------------------------------------------------------------------------
# Unit pins: policy determinism, validation, chaos schedule, transport
# ---------------------------------------------------------------------------


def test_backoff_is_deterministic_bounded_and_exponential():
    p = RetryPolicy(base_backoff_s=0.1, backoff_factor=2.0, jitter=0.25)
    for attempt in (1, 2, 3):
        d = p.backoff_s(attempt, salt=7)
        assert d == p.backoff_s(attempt, salt=7)          # replayable
        base = 0.1 * 2.0 ** (attempt - 1)
        assert base * 0.75 <= d <= base * 1.25            # jitter band
    assert p.backoff_s(1, salt=1) != p.backoff_s(1, salt=2)  # de-synced
    assert RetryPolicy(base_backoff_s=0.0).backoff_s(1) == 0.0


@pytest.mark.parametrize("bad", [
    dict(max_attempts=0), dict(base_backoff_s=-1.0),
    dict(backoff_factor=0.5), dict(jitter=2.0), dict(deadline_s=0.0),
])
def test_retry_policy_validates(bad):
    with pytest.raises(ResilienceError):
        RetryPolicy(**bad)


@pytest.mark.parametrize("bad", [
    dict(mode="nope"), dict(p=1.5), dict(times=-1), dict(hop_k=-1),
    dict(slow_s=-0.1), dict(only_backend="tpu"),
])
def test_chaos_config_validates(bad):
    with pytest.raises(ResilienceError):
        ChaosConfig(**bad)


def test_breaker_validates_and_snapshots():
    with pytest.raises(ResilienceError):
        CircuitBreaker(k=0)
    b = CircuitBreaker(k=2, cooloff_s=5.0, clock=lambda: 0.0)
    assert b.snapshot() == {"state": "closed", "consecutive_failures": 0,
                            "trips": 0, "probes": 0}


def test_chaos_schedule_decisions_replay_from_seed():
    class _S:                                 # minimal session stand-in
        def __init__(self, sid):
            self.sid = sid

    def stream(seed):
        sched = ChaosSchedule(ChaosConfig(mode="dispatch", p=0.5,
                                          seed=seed))
        return tuple(sched.decide([_S(0)], "sim") is not None
                     for _ in range(32))

    assert stream(5) == stream(5)
    assert stream(5) != stream(6)
    assert any(stream(5)) and not all(stream(5))   # p strictly inside


def test_chaos_transport_delegates_everything_but_armed_hops():
    class Inner:
        impl = "jnp"

        def hop(self, rnd, rnd_idx, meta, acc):
            return ("hopped", rnd_idx)

    tp = ChaosTransport(Inner(), ChaosConfig(mode="hop", hop_k=2))
    assert tp.impl == "jnp"                       # attribute passthrough
    assert tp.hop(None, 1, None, None) == ("hopped", 1)
    with pytest.raises(ChaosError):
        tp.hop(None, 2, None, None)
    assert ChaosTransport(Inner(), None).hop(None, 2, None, None) \
        == ("hopped", 2)


def test_deadline_exceeded_is_a_runtime_error():
    assert issubclass(DeadlineExceeded, RuntimeError)


# ---------------------------------------------------------------------------
# Streaming ring: fault in an overlapped in-flight batch (sim cell)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["dispatch", "hop"])
def test_streaming_inflight_fault_settles_retries_bit_identical(mode):
    """Two batches overlapped in a depth-2 ring; the fault is pinned to
    the *second* batch (injected at issue time, while the first is
    still in flight on the device) and only surfaces when its slot
    settles at reveal.  The ring drains, the retry wins, and every
    session reveals bit-identical to the fault-free depth-1 sequential
    run."""
    vals = _vals(S=8)
    batching = BatchingConfig(max_batch=4, max_age=1e9)
    seq, seq_ss = _service(S=8, vals=vals, batching=batching,
                           stream=StreamConfig(depth=1))
    assert seq.pump(force=True) == 8
    ref = np.stack([s.result for s in seq_ss])

    svc, ss = _service(
        S=8, vals=vals, batching=batching,
        retry=RetryPolicy(max_attempts=3, base_backoff_s=0.0),
        chaos=ChaosConfig(mode=mode, hop_k=0, times=1, poison_sids=(5,)),
        stream=StreamConfig(depth=2))
    assert svc.pump(force=True) == 8
    assert np.array_equal(np.stack([s.result for s in ss]), ref)
    res = svc.stats["resilience"]
    assert res["chaos_injected"] == 1 and res["retries"] == 1
    assert res["quarantined"] == 0 and res["dead_letter"] == ()
    assert all(s.state is SessionState.REVEALED for s in ss)
    # the ring really overlapped: both batches were in flight at once
    depth = svc.metrics.snapshot()["gauges"]["executor.pipeline_depth"]
    assert depth == 2.0


# ---------------------------------------------------------------------------
# Mesh half of the grid (forced 8-device subprocess)
# ---------------------------------------------------------------------------


def _run_sub(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


_MESH_CHAOS = """
import numpy as np
from repro.runtime import compat
from repro.runtime.chaos import ChaosConfig
from repro.runtime.resilience import CircuitBreaker, RetryPolicy
from repro.service import AggregationService, BatchingConfig, SessionParams
from repro.service.session import SessionState

n, elems, S, BLOCKS = 8, 48, 4, 4
rng = np.random.default_rng(7)
vals = rng.normal(size=(BLOCKS * S, n, elems)).astype(np.float32) * 0.3
params = SessionParams(n_nodes=n, elems=elems, cluster_size=4, redundancy=3)
mesh = compat.make_mesh((n,), ("data",))


def build(transport="mesh", **kw):
    return AggregationService(
        params, batching=BatchingConfig(max_batch=64, max_age=1e9),
        transport=transport, mesh=mesh if transport == "mesh" else None,
        **kw)


def feed(svc, block):
    out = []
    for i in range(S):
        s = svc.open(now=0.0)
        for slot in range(n):
            s.contribute(slot, vals[block * S + i, slot])
        svc.seal(s.sid, now=0.0)
        out.append(s)
    return out


# sim-oracle references, one per sid block (sim == mesh by construction)
ref_svc = build(transport="sim")
ref = []
for b in range(BLOCKS):
    ss = feed(ref_svc, b)
    assert ref_svc.pump(force=True) == S
    ref.append(np.stack([s.result for s in ss]))

# -- recovered: one transient fault per mode, mesh batch retries clean --
for chaos in (ChaosConfig(mode="dispatch", times=1),
              ChaosConfig(mode="compile", times=1),
              ChaosConfig(mode="hop", hop_k=0, times=1),
              ChaosConfig(mode="slow", slow_s=1.5, times=1)):
    svc = build(retry=RetryPolicy(
        max_attempts=3, base_backoff_s=0.0,
        deadline_s=1.0 if chaos.mode == "slow" else None), chaos=chaos)
    ss = feed(svc, 0)
    assert svc.pump(force=True) == S
    assert np.array_equal(np.stack([s.result for s in ss]), ref[0]), \
        chaos.mode
    res = svc.executor.resilience
    assert res["chaos_injected"] == 1 and res["retries"] >= 1, chaos.mode
    assert res["quarantined"] == 0, chaos.mode
print("MESH RECOVERED OK")

# -- bisected/quarantined: hop fault pinned to one session, eager mesh
# path through MeshTransport(wrap_inner=ChaosTransport) --
poison = 2
svc = build(retry=RetryPolicy(max_attempts=2, base_backoff_s=0.0),
            chaos=ChaosConfig(mode="hop", hop_k=0, poison_sids=(poison,)))
ss = feed(svc, 0)
assert svc.pump(force=True) == S
for i, s in enumerate(ss):
    if i == poison:
        assert s.state is SessionState.FAILED and "chaos" in s.failed_reason
    else:
        assert s.state is SessionState.REVEALED
        assert np.array_equal(s.result, ref[0][i])
res = svc.executor.resilience
assert res["bisections"] >= 1 and res["quarantined"] == 1
assert res["dead_letter"][0][0] == poison
print("MESH QUARANTINE OK")

# -- degrade ladder: K=2 mesh failures trip the breaker, batches run on
# the sim fallback bit-identical, a post-cooloff probe closes it again --
clk = {"t": 0.0}
brk = CircuitBreaker(k=2, cooloff_s=50.0, clock=lambda: clk["t"])
svc = build(retry=RetryPolicy(max_attempts=3, base_backoff_s=0.0),
            breaker=brk,
            chaos=ChaosConfig(mode="dispatch", only_backend="mesh",
                              times=3))
ss = feed(svc, 0)                      # mesh fails x2 -> open; sim runs
assert svc.pump(force=True) == S
assert np.array_equal(np.stack([s.result for s in ss]), ref[0])
assert brk.state == "open" and brk.trips == 1
assert svc.executor.degraded_batches == 1

ss = feed(svc, 1)                      # still open: straight to sim
assert svc.pump(force=True) == S
assert np.array_equal(np.stack([s.result for s in ss]), ref[1])
assert svc.executor.degraded_batches == 2

clk["t"] = 100.0                       # probe mesh; 3rd injection kills it
ss = feed(svc, 2)
assert svc.pump(force=True) == S
assert np.array_equal(np.stack([s.result for s in ss]), ref[2])
assert brk.probes == 1 and brk.state == "open"
assert svc.executor.degraded_batches == 3

clk["t"] = 200.0                       # probe again; chaos exhausted:
ss = feed(svc, 3)                      # the REAL mesh runs and closes it
assert svc.pump(force=True) == S
assert np.array_equal(np.stack([s.result for s in ss]), ref[3])
assert brk.state == "closed" and brk.probes == 2
assert svc.executor.degraded_batches == 3
print("MESH DEGRADE LADDER OK")
"""


@pytest.mark.mesh
@pytest.mark.slow
def test_mesh_chaos_grid_and_degrade_ladder_8dev():
    """The mesh column of the conformance grid: recovery for every
    chaos mode, hop-fault quarantine through the in-shard_map
    ChaosTransport, and the full breaker ladder (trip -> degraded
    sim batches bit-identical -> failed probe -> closing probe)."""
    r = _run_sub(_MESH_CHAOS)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "MESH RECOVERED OK" in r.stdout
    assert "MESH QUARANTINE OK" in r.stdout
    assert "MESH DEGRADE LADDER OK" in r.stdout


_MESH_STREAM = """
import numpy as np
from repro.runtime import compat
from repro.runtime.chaos import ChaosConfig
from repro.runtime.resilience import RetryPolicy
from repro.service import (AggregationService, BatchingConfig, SessionParams,
                           StreamConfig)
from repro.service.session import SessionState

n, elems, S = 8, 48, 8
rng = np.random.default_rng(11)
vals = rng.normal(size=(S, n, elems)).astype(np.float32) * 0.3
params = SessionParams(n_nodes=n, elems=elems, cluster_size=4, redundancy=3)
mesh = compat.make_mesh((n,), ("data",))
batching = BatchingConfig(max_batch=4, max_age=1e9)


def feed(svc):
    out = []
    for i in range(S):
        s = svc.open(now=0.0)
        for slot in range(n):
            s.contribute(slot, vals[i, slot])
        svc.seal(s.sid, now=0.0)
        out.append(s)
    return out


# fault-free sequential sim oracle (fresh service => same sids/pad keys)
seq = AggregationService(params, batching=batching, transport="sim",
                         stream=StreamConfig(depth=1))
ss = feed(seq)
assert seq.pump(force=True) == S
ref = np.stack([s.result for s in ss])

# depth-2 mesh ring: fault pinned to the second overlapped batch,
# injected while the first is in flight, surfaced when its slot settles
svc = AggregationService(
    params, batching=batching, transport="mesh", mesh=mesh,
    retry=RetryPolicy(max_attempts=3, base_backoff_s=0.0),
    chaos=ChaosConfig(mode="dispatch", times=1, poison_sids=(5,)),
    stream=StreamConfig(depth=2))
ss = feed(svc)
assert svc.pump(force=True) == S
assert np.array_equal(np.stack([s.result for s in ss]), ref)
res = svc.executor.resilience
assert res["chaos_injected"] == 1 and res["retries"] == 1
assert res["quarantined"] == 0
assert all(s.state is SessionState.REVEALED for s in ss)
assert svc.metrics.snapshot()["gauges"]["executor.pipeline_depth"] == 2.0
print("MESH STREAM RECOVERED OK")
"""


@pytest.mark.mesh
@pytest.mark.slow
def test_mesh_streaming_inflight_fault_recovers_8dev():
    """Mesh cell of the streaming-fault scenario: a depth-2 ring on the
    8-device mesh transport with the fault injected into the second
    overlapped batch, surfaced at settle, retried, and revealed
    bit-identical to the sequential sim oracle."""
    r = _run_sub(_MESH_STREAM)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "MESH STREAM RECOVERED OK" in r.stdout
